//! Edge-case integration tests: boundary shapes, degenerate clusterings,
//! and failure-injection corners across the public API.

use apnc::coordinator::cluster_job::{self, ClusterConfig};
use apnc::coordinator::driver::{Pipeline, PipelineConfig};
use apnc::coordinator::sample::SampleMode;
use apnc::coordinator::DataBlock;
use apnc::data::{registry, synth, Dataset};
use apnc::embedding::{nystrom, Method};
use apnc::kernels::Kernel;
use apnc::linalg::{eigh, eigh_rand, EigConfig, EigSolver, Matrix};
use apnc::mapreduce::{Engine, EngineConfig};
use apnc::rng::Pcg;
use apnc::runtime::{Compute, DistKind};

fn pjrt_or_skip() -> Option<Compute> {
    let dir = Compute::default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return None;
    }
    Some(Compute::pjrt(&dir).expect("pjrt backend"))
}

#[test]
fn embed_single_row_and_single_sample() {
    for compute in [Some(Compute::reference()), pjrt_or_skip()].into_iter().flatten() {
        let x = vec![0.5f32, -0.25, 1.0];
        let samples = vec![0.1f32, 0.2, 0.3];
        let r_t = vec![2.0f32];
        let y = compute
            .embed(&x, 1, 3, &samples, 1, &r_t, 1, Kernel::Rbf { gamma: 0.5 })
            .unwrap();
        assert_eq!(y.len(), 1);
        let kv = Kernel::Rbf { gamma: 0.5 }.eval(&x, &samples) as f32;
        assert!((y[0] - 2.0 * kv).abs() < 1e-5, "{} vs {}", y[0], 2.0 * kv);
    }
}

#[test]
fn embed_rows_exactly_at_block_boundary() {
    let Some(pjrt) = pjrt_or_skip() else { return };
    let reference = Compute::reference();
    let mut rng = Pcg::seeded(5);
    // 1024 = exactly one artifact block; 1025 = one full + one padded row
    for rows in [1024usize, 1025, 2048] {
        let d = 8;
        let x: Vec<f32> = (0..rows * d).map(|_| rng.normal() as f32).collect();
        let samples: Vec<f32> = (0..16 * d).map(|_| rng.normal() as f32).collect();
        let r_t: Vec<f32> = (0..16 * 4).map(|_| rng.normal() as f32 * 0.2).collect();
        let k = Kernel::Linear;
        let a = pjrt.embed(&x, rows, d, &samples, 16, &r_t, 4, k).unwrap();
        let b = reference.embed(&x, rows, d, &samples, 16, &r_t, 4, k).unwrap();
        assert_eq!(a.len(), rows * 4);
        for (x1, x2) in a.iter().zip(&b) {
            assert!((x1 - x2).abs() < 1e-3, "rows={rows}");
        }
    }
}

#[test]
fn assign_k_equals_one() {
    for compute in [Some(Compute::reference()), pjrt_or_skip()].into_iter().flatten() {
        let mut rng = Pcg::seeded(6);
        let y: Vec<f32> = (0..40 * 3).map(|_| rng.normal() as f32).collect();
        let c = vec![0.0f32; 3];
        let out = compute.assign(&y, 40, 3, &c, 1, DistKind::L2Sq).unwrap();
        assert!(out.assign.iter().all(|&a| a == 0));
        assert_eq!(out.g[0], 40.0);
    }
}

#[test]
fn cluster_k_equals_n_points() {
    // every point its own cluster: objective ~ 0
    let mut rng = Pcg::seeded(7);
    let n = 12;
    let x: Vec<f32> = (0..n * 4).map(|_| rng.normal() as f32).collect();
    let blocks = DataBlock::partition(&x, n, 4, 4);
    let engine = Engine::new(EngineConfig::with_workers(2));
    let out = cluster_job::run(
        &engine,
        &Compute::reference(),
        &blocks,
        4,
        DistKind::L2Sq,
        &ClusterConfig { k: n, max_iters: 10, tol: 0.0, seed: 8, ..Default::default() },
    )
    .unwrap();
    assert!(out.obj_curve.last().unwrap() < &1e-6, "{:?}", out.obj_curve);
}

#[test]
fn pipeline_with_l_larger_than_n() {
    // sampling caps at n; Nystrom caps m at l
    let ds = registry::generate("moons", 120, 9);
    let cfg = PipelineConfig {
        method: Method::Nystrom,
        l: 10_000,
        m: 10_000,
        workers: 2,
        max_iters: 5,
        sample_mode: SampleMode::Exact,
        seed: 9,
        ..Default::default()
    };
    let out = Pipeline::with_compute(cfg, Compute::reference()).run(&ds).unwrap();
    assert!(out.l_actual <= 120);
    assert!(out.m_actual <= out.l_actual);
    assert_eq!(out.labels.len(), 120);
}

#[test]
fn pipeline_single_block_single_worker() {
    let ds = registry::generate("moons", 200, 10);
    let cfg = PipelineConfig {
        method: Method::StableDist,
        l: 40,
        m: 32,
        workers: 1,
        block_rows: 100_000,
        max_iters: 5,
        seed: 10,
        ..Default::default()
    };
    let out = Pipeline::with_compute(cfg, Compute::reference()).run(&ds).unwrap();
    assert_eq!(out.labels.len(), 200);
    // one block -> one map task for the embed round plus one for the
    // portion-concat pass (Algorithm 1's final map phase)
    assert_eq!(out.embed_metrics.map_tasks, 2);
}

#[test]
fn duplicate_points_rank_deficient_kernel() {
    // all-identical sample points: K_LL is rank 1; the whitening must not
    // produce NaNs and the pipeline must still emit a valid clustering
    let mut x = Vec::new();
    let mut labels = Vec::new();
    for i in 0..200 {
        let c = (i % 2) as f32;
        x.extend_from_slice(&[c * 10.0, c * 10.0 + 1.0]);
        labels.push(i % 2);
    }
    let ds = Dataset::new("dup", 2, 2, x, labels.iter().map(|&l| l as u32).collect());
    let cfg = PipelineConfig {
        method: Method::Nystrom,
        l: 16,
        m: 8,
        workers: 2,
        max_iters: 5,
        kernel: Some(Kernel::Rbf { gamma: 0.1 }),
        seed: 11,
        ..Default::default()
    };
    let out = Pipeline::with_compute(cfg, Compute::reference()).run(&ds).unwrap();
    assert_eq!(out.labels.len(), 200);
    assert!(out.nmi > 0.9, "two obvious point-clusters: nmi {}", out.nmi);
}

#[test]
fn coeff_fit_on_two_samples() {
    let samples = vec![0.0f32, 0.0, 1.0, 1.0];
    let coeffs = nystrom::fit(&samples, 2, Kernel::Rbf { gamma: 1.0 }, 5);
    assert_eq!(coeffs.l(), 2);
    assert!(coeffs.m() <= 2);
    assert!(coeffs.blocks[0].r_t.iter().all(|v| v.is_finite()));
}

#[test]
fn heavy_fault_rate_still_correct() {
    let ds = synth::moons("m", 300, 4, 0.05, 12);
    let base = PipelineConfig {
        method: Method::Nystrom,
        l: 32,
        m: 16,
        workers: 4,
        block_rows: 32,
        max_iters: 5,
        seed: 12,
        ..Default::default()
    };
    let clean = Pipeline::with_compute(base.clone(), Compute::reference()).run(&ds).unwrap();
    let mut faulty = base;
    // 60% per-attempt failure: most tasks need several attempts (p^4 ~ 13%
    // of tasks would exhaust 4 attempts, so allow more)
    faulty.faults = apnc::mapreduce::FaultPlan {
        map_failure_prob: 0.6,
        max_attempts: 24,
        seed: 13,
        ..Default::default()
    };
    let out = Pipeline::with_compute(faulty, Compute::reference()).run(&ds).unwrap();
    assert_eq!(out.labels, clean.labels);
    assert!(out.embed_metrics.map_retries + out.cluster_metrics.map_retries > 10);
}

#[test]
fn eigh_rand_degenerate_panel_falls_back_to_dense_exactly() {
    // m + oversample >= l leaves no room for a sketch: the solver must
    // hand the call to the dense path bit-for-bit and draw NOTHING from
    // the rng (so downstream sampling stays on the dense trajectory)
    let n = 24usize;
    let mut rng = Pcg::seeded(21);
    let b = Matrix::from_fn(n, n, |_, _| rng.normal());
    let mut a = b.matmul_nt(&b);
    for i in 0..n {
        a[(i, i)] += 1.0;
    }
    let dense = eigh(&a);
    for m in [20usize, n] {
        // oversample 8: m + 8 >= 24 in both cases (m == l is the extreme)
        let mut r = Pcg::seeded(22);
        let before = r.clone().next_u64();
        let got = eigh_rand(&a, m, 8, 2, &mut r);
        assert_eq!(r.next_u64(), before, "fallback consumed rng state, m={m}");
        let want_vals: Vec<u64> = dense.values[n - m..].iter().map(|v| v.to_bits()).collect();
        let got_vals: Vec<u64> = got.values.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got_vals, want_vals, "values not bit-equal to dense, m={m}");
        for c in 0..m {
            for rr in 0..n {
                assert_eq!(
                    got.vectors[(rr, c)].to_bits(),
                    dense.vectors[(rr, n - m + c)].to_bits(),
                    "vector entry ({rr},{c}) not bit-equal to dense, m={m}"
                );
            }
        }
    }
}

#[test]
fn eigh_rand_survives_rank_deficient_gram() {
    // duplicate sampled rows: K_LL has massively repeated rows (rank ~ 4
    // for an RBF gram over 4 distinct points) — the MGS zero-norm guard
    // must keep the sketch finite and the leading Ritz values accurate
    let (l, d) = (48usize, 3usize);
    let mut rng = Pcg::seeded(23);
    let distinct: Vec<f32> = (0..4 * d).map(|_| rng.normal() as f32).collect();
    let samples: Vec<f32> = (0..l)
        .flat_map(|i| distinct[(i % 4) * d..(i % 4 + 1) * d].to_vec())
        .collect();
    let gram = Kernel::Rbf { gamma: 0.3 }.gram(&samples, d);
    let dense = eigh(&gram);
    let m = 6usize;
    let got = eigh_rand(&gram, m, 8, 2, &mut Pcg::seeded(24));
    assert!(got.values.iter().all(|v| v.is_finite()));
    assert!(got.vectors.data().iter().all(|v| v.is_finite()));
    // the 4 genuine eigenvalues sit at the tail of both ascending lists
    for i in 0..4 {
        let want = dense.values[l - 4 + i];
        let ritz = got.values[m - 4 + i];
        assert!(
            (ritz - want).abs() <= 1e-8 * want.abs().max(1.0),
            "rank-deficient Ritz value {i}: {ritz} vs dense {want}"
        );
    }
    // and the full Nyström fit stays finite through the randomized path
    let eig = EigConfig { solver: EigSolver::Randomized, oversample: 8, power_iters: 2 };
    let (coeffs, used) =
        nystrom::fit_with(&samples, d, Kernel::Rbf { gamma: 0.3 }, m, &eig, &mut Pcg::seeded(25));
    assert_eq!(used, EigSolver::Randomized);
    assert!(coeffs.blocks[0].r_t.iter().all(|v| v.is_finite()));
}

#[test]
fn builder_rejects_bad_eig_knobs() {
    assert!(PipelineConfig::builder().eig_oversample(0).build().is_err());
    assert!(PipelineConfig::builder().eig_power_iters(9).build().is_err());
    let ok = PipelineConfig::builder()
        .eig_solver(EigSolver::Randomized)
        .eig_oversample(1)
        .eig_power_iters(8)
        .build()
        .unwrap();
    assert_eq!(ok.eig_solver, EigSolver::Randomized);
}

#[test]
fn dataset_io_roundtrip_through_pipeline() {
    let ds = registry::generate("rings", 600, 14);
    let path = std::env::temp_dir().join(format!("apnc-edge-io-{}", std::process::id()));
    apnc::data::io::save(&ds, &path).unwrap();
    let loaded = apnc::data::io::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let cfg = PipelineConfig {
        method: Method::Nystrom,
        l: 64,
        m: 32,
        workers: 2,
        max_iters: 8,
        restarts: 3,
        seed: 14,
        ..Default::default()
    };
    let a = Pipeline::with_compute(cfg.clone(), Compute::reference()).run(&ds).unwrap();
    let b = Pipeline::with_compute(cfg, Compute::reference()).run(&loaded).unwrap();
    assert_eq!(a.labels, b.labels, "persisted dataset must cluster identically");
}
