//! Fixture tests for `apnc-lint` (`apnc::analysis`): every rule has at
//! least one must-fire and one must-pass fixture, allow annotations
//! suppress, a bare allow is itself a finding, and the shipped tree is
//! lint-clean. The fixtures drive [`lint_source`] directly — the rule
//! engine sees exactly what the binary sees, minus the file walk.

use apnc::analysis::{lint_source, lint_tree, Rule};

/// The rule list a fixture produces, in report order.
fn rules_of(path: &str, src: &str) -> Vec<Rule> {
    lint_source(path, src).into_iter().map(|f| f.rule).collect()
}

// ---- D1: unordered containers in compute/reduce modules ---------------

#[test]
fn d1_fires_on_hashmap_in_compute_scope() {
    let src =
        "fn f() {\n    let mut m = std::collections::HashMap::new();\n    m.insert(1, 2);\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![Rule::D1]);
    assert_eq!(rules_of("mapreduce/fake.rs", src), vec![Rule::D1]);
    assert_eq!(rules_of("metrics/fake.rs", src), vec![Rule::D1]);
}

#[test]
fn d1_ignores_out_of_scope_modules_and_use_lines() {
    let src =
        "fn f() {\n    let mut m = std::collections::HashMap::new();\n    m.insert(1, 2);\n}\n";
    assert_eq!(rules_of("model/fake.rs", src), vec![]);
    assert_eq!(rules_of("linalg/fake.rs", "use std::collections::HashMap;\n"), vec![]);
}

#[test]
fn d1_accepts_sort_before_iterate() {
    let src = "fn f(m: std::collections::HashMap<u32, u32>) -> Vec<(u32, u32)> {\n\
               \x20   let mut pairs: Vec<_> = m.into_iter().collect();\n\
               \x20   pairs.sort();\n\
               \x20   pairs\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![]);
}

#[test]
fn d1_respects_identifier_boundaries() {
    let src = "fn f(x: MyHashMapLike) {\n    x.touch();\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![]);
}

// ---- D2: wall-clock reads in compute/reduce modules --------------------

#[test]
fn d2_fires_on_instant_now_in_compute_scope() {
    let src = "fn f() {\n    let t0 = std::time::Instant::now();\n    drop(t0);\n}\n";
    assert_eq!(rules_of("mapreduce/fake.rs", src), vec![Rule::D2]);
    assert_eq!(rules_of("embedding/fake.rs", src), vec![Rule::D2]);
}

#[test]
fn d2_exempts_driver_telemetry_and_serving() {
    let src = "fn f() {\n    let t0 = std::time::Instant::now();\n    drop(t0);\n}\n";
    // the pipeline driver owns phase telemetry — explicit carve-out
    assert_eq!(rules_of("coordinator/driver.rs", src), vec![]);
    // serving/bench timing is out of D2's scope entirely
    assert_eq!(rules_of("model/fake.rs", src), vec![]);
}

// ---- D3: entropy discipline -------------------------------------------

#[test]
fn d3_fires_on_foreign_entropy_anywhere() {
    let src =
        "fn f() {\n    let s = std::collections::hash_map::RandomState::new();\n    drop(s);\n}\n";
    assert_eq!(rules_of("data/fake.rs", src), vec![Rule::D3]);
    assert_eq!(rules_of("model/fake.rs", src), vec![Rule::D3]);
}

#[test]
fn d3_exempts_the_pipeline_pcg() {
    let src = "fn seed_from_os() {\n    let r = OsRng;\n    drop(r);\n}\n";
    assert_eq!(rules_of("rng.rs", src), vec![]);
}

// ---- U1: SAFETY comments on unsafe sites ------------------------------

#[test]
fn u1_fires_on_uncommented_unsafe() {
    let src = "fn f(p: *mut f32) {\n    unsafe { *p = 0.0 };\n}\n";
    assert_eq!(rules_of("parallel/fake.rs", src), vec![Rule::U1]);
}

#[test]
fn u1_accepts_safety_comment_above_or_inline() {
    let above = "fn f(p: *mut f32) {\n\
                 \x20   // SAFETY: caller guarantees p is valid and exclusive\n\
                 \x20   unsafe { *p = 0.0 };\n}\n";
    assert_eq!(rules_of("parallel/fake.rs", above), vec![]);
    let inline = "fn f(p: *mut f32) {\n    unsafe { *p = 0.0 }; // SAFETY: p is valid\n}\n";
    assert_eq!(rules_of("parallel/fake.rs", inline), vec![]);
}

#[test]
fn u1_requires_the_comment_block_to_be_contiguous() {
    let gap = "fn f(p: *mut f32) {\n\
               \x20   // SAFETY: this comment is orphaned by the blank line\n\
               \n\
               \x20   unsafe { *p = 0.0 };\n}\n";
    assert_eq!(rules_of("parallel/fake.rs", gap), vec![Rule::U1]);
}

// ---- P1: panic paths in serving modules --------------------------------

#[test]
fn p1_fires_on_unwrap_in_serving_scope() {
    let src = "fn f(v: Vec<u32>) -> u32 {\n    v.into_iter().next().unwrap()\n}\n";
    assert_eq!(rules_of("model/serve.rs", src), vec![Rule::P1]);
    assert_eq!(rules_of("runtime/service.rs", src), vec![Rule::P1]);
    // the network tier is serving scope too: a panic on a connection
    // thread silently drops every request in flight on that socket
    assert_eq!(rules_of("model/net.rs", src), vec![Rule::P1]);
    assert_eq!(rules_of("model/proto.rs", src), vec![Rule::P1]);
}

#[test]
fn p1_ignores_non_serving_scope_and_poison_recovery() {
    let src = "fn f(v: Vec<u32>) -> u32 {\n    v.into_iter().next().unwrap()\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![]);
    // the lock-poisoning recovery idiom is not a panic path
    let poison = "fn g(m: &std::sync::Mutex<u32>) -> u32 {\n\
                  \x20   *m.lock().unwrap_or_else(|p| p.into_inner())\n}\n";
    assert_eq!(rules_of("model/serve.rs", poison), vec![]);
}

// ---- F1: shared-state accumulation in par_* closures -------------------

#[test]
fn f1_fires_on_lock_inside_par_extent() {
    let src = "fn f(out: &mut [f64], total: &std::sync::Mutex<f64>) {\n\
               \x20   par_chunks_mut(out, 8, |_i, chunk| {\n\
               \x20       let mut t = total.lock().unwrap_or_else(|p| p.into_inner());\n\
               \x20       for v in chunk.iter_mut() {\n\
               \x20           *t += *v;\n\
               \x20       }\n\
               \x20   });\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![Rule::F1]);
}

#[test]
fn f1_ignores_clean_closures_and_locks_outside_extents() {
    let clean = "fn f(out: &mut [f64]) {\n\
                 \x20   par_chunks_mut(out, 8, |i, chunk| {\n\
                 \x20       for v in chunk.iter_mut() {\n\
                 \x20           *v += i as f64;\n\
                 \x20       }\n\
                 \x20   });\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", clean), vec![]);
    let outside = "fn g(m: &std::sync::Mutex<u32>) -> u32 {\n\
                   \x20   *m.lock().unwrap_or_else(|p| p.into_inner())\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", outside), vec![]);
}

// ---- allows and A1 -----------------------------------------------------

#[test]
fn allow_with_reason_suppresses() {
    let src = "fn f() {\n\
               \x20   // apnc-lint: allow(D1) lookup-only cache, never iterated\n\
               \x20   let mut m = std::collections::HashMap::new();\n\
               \x20   m.insert(1, 2);\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![]);
}

#[test]
fn allow_covers_multiple_rules_at_once() {
    let src = "fn f() {\n\
               \x20   // apnc-lint: allow(D1, D2) fixture: both rules silenced at once\n\
               \x20   let t = (std::collections::HashMap::<u32, u32>::new(), \
               std::time::Instant::now());\n\
               \x20   drop(t);\n}\n";
    assert_eq!(rules_of("mapreduce/fake.rs", src), vec![]);
}

#[test]
fn bare_allow_is_a_finding_and_does_not_suppress() {
    let src = "fn f() {\n\
               \x20   // apnc-lint: allow(D1)\n\
               \x20   let mut m = std::collections::HashMap::new();\n\
               \x20   m.insert(1, 2);\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![Rule::A1, Rule::D1]);
}

#[test]
fn allow_naming_an_unknown_rule_is_a_finding() {
    let src = "fn f() {\n    // apnc-lint: allow(Z9) not a rule\n    let x = 1;\n    drop(x);\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![Rule::A1]);
}

#[test]
fn allow_is_line_scoped_not_file_scoped() {
    let src = "fn f() {\n\
               \x20   // apnc-lint: allow(D1) only covers the next line\n\
               \x20   let mut a = std::collections::HashMap::new();\n\
               \x20   let mut b = std::collections::HashMap::new();\n\
               \x20   a.insert(1, 2);\n\
               \x20   b.insert(3, 4);\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![Rule::D1]);
}

// ---- scanner discipline ------------------------------------------------

#[test]
fn tokens_in_strings_and_comments_never_fire() {
    let src = "fn f() -> &'static str {\n\
               \x20   // HashMap::new() in a comment is fine, unsafe too\n\
               \x20   \"HashMap::new() and Instant::now() in a string are fine\"\n}\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![]);
}

#[test]
fn cfg_test_regions_are_exempt() {
    let src = "#[cfg(test)]\n\
               mod tests {\n\
               \x20   #[test]\n\
               \x20   fn t() {\n\
               \x20       let mut m = std::collections::HashMap::new();\n\
               \x20       m.insert(1, std::time::Instant::now());\n\
               \x20   }\n\
               }\n";
    assert_eq!(rules_of("linalg/fake.rs", src), vec![]);
}

#[test]
fn findings_display_in_the_documented_shape() {
    let findings =
        lint_source("linalg/fake.rs", "fn f() { let m = std::collections::HashMap::new(); }");
    assert_eq!(findings.len(), 1);
    let line = findings[0].to_string();
    assert!(
        line.starts_with("linalg/fake.rs:1 · D1 · "),
        "unexpected finding shape: {line}"
    );
}

// ---- the shipped tree --------------------------------------------------

#[test]
fn shipped_tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let findings = lint_tree(&root).expect("walking the crate sources");
    assert!(
        findings.is_empty(),
        "apnc-lint found {} issue(s) in the shipped tree:\n{}",
        findings.len(),
        findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
    );
}
