//! Model persistence + serving integration tests: the determinism
//! contract extended to the serving path.
//!
//! save → load → predict must be bit-identical to the in-memory model,
//! across methods, thread counts, chunk sizes, shard counts, and
//! concurrent clients; a dead shard must fail requests with its recorded
//! cause; corrupted or truncated model files must be rejected with an
//! error.

use std::sync::Arc;

use apnc::coordinator::driver::{Pipeline, PipelineConfig};
use apnc::data::{registry, Dataset};
use apnc::embedding::Method;
use apnc::model::shard::drive_clients;
use apnc::model::ApncModel;
use apnc::runtime::Compute;

fn fit_model(method: Method, seed: u64) -> (Dataset, ApncModel) {
    let ds = registry::generate("moons", 400, seed);
    let mut b = PipelineConfig::builder()
        .method(method)
        .l(48)
        .m(32)
        .max_iters(10)
        .workers(3)
        .block_rows(128)
        .seed(seed);
    if method == Method::StableDist {
        // SD needs more projections than Nystrom needs eigenvectors
        b = b.m(96).l(64);
    }
    let cfg = b.build().unwrap();
    let (model, _report) =
        Pipeline::with_compute(cfg, Compute::reference()).fit(&ds).unwrap();
    (ds, model)
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apnc-roundtrip-{name}-{}", std::process::id()))
}

fn roundtrip_bit_identical(method: Method, tag: &str, seed: u64) {
    let (ds, model) = fit_model(method, seed);
    let path = tmp(tag);
    model.save(&path).unwrap();
    let loaded = ApncModel::load_with(&path, Compute::reference()).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.method(), method);
    assert_eq!(loaded.kernel(), model.kernel());
    assert_eq!((loaded.d(), loaded.m(), loaded.l(), loaded.k()), (model.d(), model.m(), model.l(), model.k()));
    assert_eq!(loaded.dist(), model.dist());
    assert_eq!(loaded.centroids(), model.centroids());
    assert_eq!(loaded.provenance(), model.provenance());

    // training data and fresh out-of-sample points, several chunkings:
    // labels must be bit-identical between the in-memory and loaded model
    let fresh = registry::generate("moons", 150, seed ^ 0xFF);
    for x in [&ds.x, &fresh.x] {
        let want = model.predict_batch(x, 0).unwrap();
        for chunk in [0usize, 1, 7, 64, 10_000] {
            assert_eq!(loaded.predict_batch(x, chunk).unwrap(), want, "chunk={chunk}");
        }
        assert_eq!(loaded.predict(x).unwrap(), want);
    }
}

#[test]
fn nystrom_roundtrip_bit_identical() {
    roundtrip_bit_identical(Method::Nystrom, "nys", 101);
}

#[test]
fn stable_dist_roundtrip_bit_identical() {
    roundtrip_bit_identical(Method::StableDist, "sd", 102);
}

#[test]
fn ensemble_roundtrip_preserves_every_block() {
    // q > 1 exercises the multi-block section of the format
    let (ds, model) = fit_model(Method::EnsembleNystrom, 103);
    assert!(model.coeffs().blocks.len() > 1, "ensemble should fit multiple blocks");
    let path = tmp("enys");
    model.save(&path).unwrap();
    let loaded = ApncModel::load_with(&path, Compute::reference()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.coeffs().blocks.len(), model.coeffs().blocks.len());
    assert_eq!(loaded.predict_batch(&ds.x, 0).unwrap(), model.predict_batch(&ds.x, 0).unwrap());
}

#[test]
fn predictions_identical_for_any_thread_count() {
    let (ds, model) = fit_model(Method::Nystrom, 104);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    for threads in [1usize, 2, 7, 8] {
        apnc::parallel::set_threads(threads);
        let got = model.predict_batch(&ds.x, 0).unwrap();
        apnc::parallel::set_threads(0);
        assert_eq!(got, want, "threads={threads}");
    }
}

#[test]
fn run_fit_and_serving_agree_end_to_end() {
    // the acceptance contract: Pipeline::run labels == fit + model
    // self-prediction == save/load/serve prediction, for both methods
    for (method, seed) in [(Method::Nystrom, 105u64), (Method::StableDist, 106)] {
        let (ds, model) = fit_model(method, seed);
        let cfg_labels = {
            let mut b = PipelineConfig::builder()
                .method(method)
                .l(48)
                .m(32)
                .max_iters(10)
                .workers(3)
                .block_rows(128)
                .seed(seed);
            if method == Method::StableDist {
                b = b.m(96).l(64);
            }
            Pipeline::with_compute(b.build().unwrap(), Compute::reference())
                .run(&ds)
                .unwrap()
                .labels
        };
        let direct = model.predict_batch(&ds.x, 0).unwrap();
        assert_eq!(direct, cfg_labels, "{method:?}: model predict != batch labels");

        let path = tmp(&format!("serve-{seed}"));
        model.save(&path).unwrap();
        let handle =
            ApncModel::load_with(&path, Compute::reference()).unwrap().serve().unwrap();
        std::fs::remove_file(&path).ok();
        let d = ds.d;
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let h = handle.clone();
                let x = &ds.x;
                let want = &direct;
                scope.spawn(move || {
                    // each client predicts interleaved batches; every label
                    // must match the in-memory prediction bit-for-bit
                    let rows = x.len() / d;
                    let batch = 64usize;
                    let mut lo = (t * 17) % rows;
                    for _ in 0..6 {
                        let hi = (lo + batch).min(rows);
                        let got = h.predict(&x[lo * d..hi * d]).unwrap();
                        assert_eq!(&got[..], &want[lo..hi], "client {t} batch at {lo}");
                        lo = (lo + batch) % rows.max(1);
                    }
                });
            }
        });
    }
}

#[test]
fn sharded_serving_bit_identical_across_shard_counts() {
    // the PR-4 acceptance contract: N shards, >= 4 concurrent clients,
    // labels bit-identical to in-memory predict_batch for N in {1, 2, 8}
    let (ds, model) = fit_model(Method::Nystrom, 110);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    for shards in [1usize, 2, 8] {
        let handle = model.clone().serve_sharded(shards).unwrap();
        assert_eq!(handle.shard_count(), shards);
        // drive_clients asserts every response equals the oracle
        let report = drive_clients(&handle, &x, ds.d, &want, 4, 12, 64);
        assert_eq!(
            report.total_rows,
            report.per_shard_rows.iter().sum::<usize>(),
            "shards={shards}: per-shard counts must cover the traffic"
        );
        assert_eq!(report.per_shard_rows.len(), shards);
        if shards > 1 {
            assert!(
                report.per_shard_rows.iter().filter(|&&r| r > 0).count() > 1,
                "shards={shards}: round robin must spread load, got {:?}",
                report.per_shard_rows
            );
        }
        // direct calls through the router agree too
        assert_eq!(handle.predict(&ds.x).unwrap(), want, "shards={shards}");
        assert_eq!(handle.predict_batch(&ds.x, 37).unwrap(), want, "shards={shards}");
    }
}

#[test]
fn sharded_serving_survives_save_load() {
    // save -> load -> shard: the served model is a fresh deserialization
    let (ds, model) = fit_model(Method::StableDist, 111);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    let path = tmp("sharded");
    model.save(&path).unwrap();
    let handle = ApncModel::load_with(&path, Compute::reference())
        .unwrap()
        .serve_sharded(3)
        .unwrap();
    std::fs::remove_file(&path).ok();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    let report = drive_clients(&handle, &x, ds.d, &want, 4, 9, 50);
    assert_eq!(report.total_rows, report.per_shard_rows.iter().sum::<usize>());
}

#[test]
fn dead_shard_fails_with_cause_and_others_keep_serving() {
    let (ds, model) = fit_model(Method::Nystrom, 112);
    let rows = 48usize;
    let want = model.predict_batch(&ds.x[..rows * ds.d], 0).unwrap();
    let handle = model.serve_sharded(3).unwrap();
    handle.shard(1).shutdown();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    let (mut oks, mut errs) = (0usize, 0usize);
    // fresh round-robin cursor: requests land on shards 0,1,2,0,1,2
    for i in 0..6 {
        match handle.predict_shared(&x, 0..rows, 0) {
            Ok(labels) => {
                assert_eq!(labels, want, "request {i}");
                oks += 1;
            }
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("shut down by explicit request"),
                    "dead-shard error must carry its cause, got: {msg}"
                );
                errs += 1;
            }
        }
    }
    assert_eq!((oks, errs), (4, 2), "exactly the dead shard's turns must fail");
}

#[test]
fn corrupted_and_truncated_files_are_rejected() {
    let (_ds, model) = fit_model(Method::Nystrom, 107);
    let path = tmp("corrupt");
    model.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // truncations at several depths (magic, header, payload, checksum)
    for cut in [0usize, 3, 8, 24, bytes.len() / 3, bytes.len() - 1] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(
            ApncModel::load_with(&path, Compute::reference()).is_err(),
            "truncation at {cut} bytes was accepted"
        );
    }

    // single flipped bytes anywhere must be caught (checksum or header
    // validation), never silently accepted
    for pos in [8usize, 12, 40, bytes.len() / 2, bytes.len() - 4] {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x10;
        std::fs::write(&path, &corrupt).unwrap();
        assert!(
            ApncModel::load_with(&path, Compute::reference()).is_err(),
            "flipped byte at {pos} was accepted"
        );
    }

    // wrong magic
    let mut wrong = bytes.clone();
    wrong[..4].copy_from_slice(b"NOPE");
    std::fs::write(&path, &wrong).unwrap();
    let err = ApncModel::load_with(&path, Compute::reference()).unwrap_err().to_string();
    assert!(err.contains("not an APNC model"), "{err}");

    // intact bytes still load (the fixture itself is valid)
    std::fs::write(&path, &bytes).unwrap();
    assert!(ApncModel::load_with(&path, Compute::reference()).is_ok());
    std::fs::remove_file(&path).ok();
}
