//! Model persistence + serving integration tests: the determinism
//! contract extended to the serving path.
//!
//! save → load → predict must be bit-identical to the in-memory model,
//! across methods, thread counts, chunk sizes, shard counts, coalescing
//! windows, and concurrent (sync or async) clients; a hot swap under
//! load must drop no request and produce responses bit-identical to
//! exactly one model epoch — never a blend; a dead shard must fail
//! requests with its recorded cause; corrupted or truncated model files
//! must be rejected with an error.

use std::sync::Arc;
use std::time::Duration;

use apnc::coordinator::driver::{Pipeline, PipelineConfig};
use apnc::data::{registry, Dataset};
use apnc::embedding::Method;
use apnc::linalg::{EigProvenance, EigSolver};
use apnc::model::serve::BatchWindow;
use apnc::model::shard::drive_clients;
use apnc::model::ApncModel;
use apnc::runtime::Compute;

fn fit_model(method: Method, seed: u64) -> (Dataset, ApncModel) {
    let ds = registry::generate("moons", 400, seed);
    let mut b = PipelineConfig::builder()
        .method(method)
        .l(48)
        .m(32)
        .max_iters(10)
        .workers(3)
        .block_rows(128)
        .seed(seed);
    if method == Method::StableDist {
        // SD needs more projections than Nystrom needs eigenvectors
        b = b.m(96).l(64);
    }
    let cfg = b.build().unwrap();
    let (model, _report) =
        Pipeline::with_compute(cfg, Compute::reference()).fit(&ds).unwrap();
    (ds, model)
}

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("apnc-roundtrip-{name}-{}", std::process::id()))
}

fn roundtrip_bit_identical(method: Method, tag: &str, seed: u64) {
    let (ds, model) = fit_model(method, seed);
    let path = tmp(tag);
    model.save(&path).unwrap();
    let loaded = ApncModel::load_with(&path, Compute::reference()).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(loaded.method(), method);
    assert_eq!(loaded.kernel(), model.kernel());
    assert_eq!(
        (loaded.d(), loaded.m(), loaded.l(), loaded.k()),
        (model.d(), model.m(), model.l(), model.k())
    );
    assert_eq!(loaded.dist(), model.dist());
    assert_eq!(loaded.centroids(), model.centroids());
    assert_eq!(loaded.provenance(), model.provenance());

    // training data and fresh out-of-sample points, several chunkings:
    // labels must be bit-identical between the in-memory and loaded model
    let fresh = registry::generate("moons", 150, seed ^ 0xFF);
    for x in [&ds.x, &fresh.x] {
        let want = model.predict_batch(x, 0).unwrap();
        for chunk in [0usize, 1, 7, 64, 10_000] {
            assert_eq!(loaded.predict_batch(x, chunk).unwrap(), want, "chunk={chunk}");
        }
        assert_eq!(loaded.predict(x).unwrap(), want);
    }
}

#[test]
fn nystrom_roundtrip_bit_identical() {
    roundtrip_bit_identical(Method::Nystrom, "nys", 101);
}

#[test]
fn stable_dist_roundtrip_bit_identical() {
    roundtrip_bit_identical(Method::StableDist, "sd", 102);
}

#[test]
fn ensemble_roundtrip_preserves_every_block() {
    // q > 1 exercises the multi-block section of the format
    let (ds, model) = fit_model(Method::EnsembleNystrom, 103);
    assert!(model.coeffs().blocks.len() > 1, "ensemble should fit multiple blocks");
    let path = tmp("enys");
    model.save(&path).unwrap();
    let loaded = ApncModel::load_with(&path, Compute::reference()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.coeffs().blocks.len(), model.coeffs().blocks.len());
    assert_eq!(loaded.predict_batch(&ds.x, 0).unwrap(), model.predict_batch(&ds.x, 0).unwrap());
}

#[test]
fn predictions_identical_for_any_thread_count() {
    let (ds, model) = fit_model(Method::Nystrom, 104);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    for threads in [1usize, 2, 7, 8] {
        apnc::parallel::set_threads(threads);
        let got = model.predict_batch(&ds.x, 0).unwrap();
        apnc::parallel::set_threads(0);
        assert_eq!(got, want, "threads={threads}");
    }
}

#[test]
fn run_fit_and_serving_agree_end_to_end() {
    // the acceptance contract: Pipeline::run labels == fit + model
    // self-prediction == save/load/serve prediction, for both methods
    for (method, seed) in [(Method::Nystrom, 105u64), (Method::StableDist, 106)] {
        let (ds, model) = fit_model(method, seed);
        let cfg_labels = {
            let mut b = PipelineConfig::builder()
                .method(method)
                .l(48)
                .m(32)
                .max_iters(10)
                .workers(3)
                .block_rows(128)
                .seed(seed);
            if method == Method::StableDist {
                b = b.m(96).l(64);
            }
            Pipeline::with_compute(b.build().unwrap(), Compute::reference())
                .run(&ds)
                .unwrap()
                .labels
        };
        let direct = model.predict_batch(&ds.x, 0).unwrap();
        assert_eq!(direct, cfg_labels, "{method:?}: model predict != batch labels");

        let path = tmp(&format!("serve-{seed}"));
        model.save(&path).unwrap();
        let handle =
            ApncModel::load_with(&path, Compute::reference()).unwrap().serve().unwrap();
        std::fs::remove_file(&path).ok();
        let d = ds.d;
        std::thread::scope(|scope| {
            for t in 0..4usize {
                let h = handle.clone();
                let x = &ds.x;
                let want = &direct;
                scope.spawn(move || {
                    // each client predicts interleaved batches; every label
                    // must match the in-memory prediction bit-for-bit
                    let rows = x.len() / d;
                    let batch = 64usize;
                    let mut lo = (t * 17) % rows;
                    for _ in 0..6 {
                        let hi = (lo + batch).min(rows);
                        let got = h.predict(&x[lo * d..hi * d]).unwrap();
                        assert_eq!(&got[..], &want[lo..hi], "client {t} batch at {lo}");
                        lo = (lo + batch) % rows.max(1);
                    }
                });
            }
        });
    }
}

#[test]
fn sharded_serving_bit_identical_across_shard_counts() {
    // the PR-4 acceptance contract: N shards, >= 4 concurrent clients,
    // labels bit-identical to in-memory predict_batch for N in {1, 2, 8}
    let (ds, model) = fit_model(Method::Nystrom, 110);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    for shards in [1usize, 2, 8] {
        let handle = model.clone().serve_sharded(shards).unwrap();
        assert_eq!(handle.shard_count(), shards);
        // drive_clients asserts every response equals the oracle
        let report = drive_clients(&handle, &x, ds.d, &want, 4, 12, 64);
        assert_eq!(
            report.total_rows,
            report.per_shard_rows.iter().sum::<usize>(),
            "shards={shards}: per-shard counts must cover the traffic"
        );
        assert_eq!(report.per_shard_rows.len(), shards);
        if shards > 1 {
            assert!(
                report.per_shard_rows.iter().filter(|&&r| r > 0).count() > 1,
                "shards={shards}: round robin must spread load, got {:?}",
                report.per_shard_rows
            );
        }
        // direct calls through the router agree too
        assert_eq!(handle.predict(&ds.x).unwrap(), want, "shards={shards}");
        assert_eq!(handle.predict_batch(&ds.x, 37).unwrap(), want, "shards={shards}");
    }
}

#[test]
fn sharded_serving_survives_save_load() {
    // save -> load -> shard: the served model is a fresh deserialization
    let (ds, model) = fit_model(Method::StableDist, 111);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    let path = tmp("sharded");
    model.save(&path).unwrap();
    let handle = ApncModel::load_with(&path, Compute::reference())
        .unwrap()
        .serve_sharded(3)
        .unwrap();
    std::fs::remove_file(&path).ok();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    let report = drive_clients(&handle, &x, ds.d, &want, 4, 9, 50);
    assert_eq!(report.total_rows, report.per_shard_rows.iter().sum::<usize>());
}

#[test]
fn dead_shard_is_healed_with_cause_and_no_request_fails() {
    let (ds, model) = fit_model(Method::Nystrom, 112);
    let rows = 48usize;
    let want = model.predict_batch(&ds.x[..rows * ds.d], 0).unwrap();
    let handle = model.serve_sharded(3).unwrap();
    handle.shard(1).inject_crash("roundtrip chaos kill");
    let x: Arc<[f32]> = ds.x.as_slice().into();
    // self-healing front-end: the killed shard's turns are routed around
    // or failed over, then it is respawned — no client ever sees an error
    for i in 0..6 {
        assert_eq!(handle.predict_shared(&x, 0..rows, 0).unwrap(), want, "request {i}");
    }
    assert!(handle.respawns() >= 1, "the killed shard must be respawned");
    assert!(
        handle
            .failures()
            .iter()
            .any(|f| f.contains("apnc-model-shard-1") && f.contains("roundtrip chaos kill")),
        "the death's cause must be recorded, not swallowed: {:?}",
        handle.failures()
    );
}

#[test]
fn coalesced_serving_bit_identical_for_any_window_and_shard_count() {
    // the PR-5 batching pin: for every shard count, coalescing window,
    // and client interleaving, batched serving == unbatched serving ==
    // in-memory predict_batch, bit for bit (drive_clients asserts each
    // response against the oracle)
    let (ds, model) = fit_model(Method::Nystrom, 120);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    for shards in [1usize, 2, 8] {
        for (max_rows, wait_us) in [(0usize, 0u64), (4, 0), (64, 200), (100_000, 500)] {
            let window = BatchWindow::new(max_rows, Duration::from_micros(wait_us));
            let handle = model.clone().serve_sharded_with(shards, window).unwrap();
            let report = drive_clients(&handle, &x, ds.d, &want, 4, 10, 16);
            assert_eq!(report.total_rows, 4 * 10 * 16, "shards={shards} window={window:?}");
            let stats = handle.per_shard_stats();
            assert_eq!(
                stats.iter().map(|s| s.rows).sum::<usize>(),
                640,
                "serving-side counters must cover the traffic: {stats:?}"
            );
            assert_eq!(stats.iter().map(|s| s.requests).sum::<usize>(), 40);
            assert!(
                stats.iter().all(|s| s.batches <= s.requests),
                "a shard can never dispatch more batches than requests: {stats:?}"
            );
        }
    }
}

#[test]
fn async_tickets_survive_save_load_and_match_the_oracle() {
    let (ds, model) = fit_model(Method::StableDist, 121);
    let want = model.predict_batch(&ds.x, 0).unwrap();
    let path = tmp("async");
    model.save(&path).unwrap();
    let handle = ApncModel::load_with(&path, Compute::reference())
        .unwrap()
        .serve_sharded_with(2, BatchWindow::new(128, Duration::from_micros(200)))
        .unwrap();
    std::fs::remove_file(&path).ok();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    // one thread, every slice in flight at once across both shards
    let batch = 25usize;
    let tickets: Vec<_> = (0..ds.n / batch)
        .map(|s| {
            let lo = s * batch;
            (lo, handle.predict_async(&x, lo..lo + batch, 0).unwrap())
        })
        .collect();
    for (lo, t) in tickets {
        let got = t.wait().unwrap();
        assert_eq!(got.epoch, 0);
        assert_eq!(&got.labels[..], &want[lo..lo + batch], "rows {lo}..");
    }
}

#[test]
fn hot_swap_under_load_never_blends_and_tags_every_epoch() {
    // the PR-5 swap pin: concurrent clients drive a sharded, coalescing
    // front-end across repeated swaps between two models whose labels
    // differ on EVERY row; each response must be bit-identical to the
    // in-memory predict_batch of the model its epoch names — a blended
    // response cannot masquerade, because the oracles disagree everywhere
    let (ds, model) = fit_model(Method::Nystrom, 122);
    let (k, m) = (model.k(), model.m());
    assert!(k >= 2, "need at least two centroids to rotate");
    // successor: same coefficients, centroid rows rotated by one — the
    // same geometry serves permuted labels, so every row's label changes
    let mut rotated = vec![0f32; model.centroids().len()];
    for c in 0..k {
        let src = ((c + 1) % k) * m;
        rotated[c * m..(c + 1) * m].copy_from_slice(&model.centroids()[src..src + m]);
    }
    let successor = ApncModel::from_parts(
        model.coeffs().clone(),
        rotated,
        k,
        model.provenance().clone(),
        Compute::reference(),
    )
    .unwrap();
    let want_a = model.predict_batch(&ds.x, 0).unwrap();
    let want_b = successor.predict_batch(&ds.x, 0).unwrap();
    assert!(
        want_a.iter().zip(&want_b).all(|(a, b)| a != b),
        "rotated centroids must relabel every row"
    );

    let window = BatchWindow::new(96, Duration::from_micros(200));
    let handle = model.clone().serve_sharded_with(3, window).unwrap();
    let x: Arc<[f32]> = ds.x.as_slice().into();
    let rows = ds.n;
    let batch = 33usize;
    let (clients, rounds, in_flight) = (4usize, 40usize, 3usize);
    let served = std::thread::scope(|scope| {
        let mut joins = Vec::new();
        for c in 0..clients {
            let h = handle.clone();
            let x = x.clone();
            let (want_a, want_b) = (&want_a, &want_b);
            joins.push(scope.spawn(move || {
                let mut count = 0usize;
                for r in 0..rounds {
                    // keep several async requests in flight while swaps
                    // land underneath
                    let tickets: Vec<_> = (0..in_flight)
                        .map(|j| {
                            let lo = (c * 17 + r * 31 + j * 7) % (rows - batch);
                            (lo, h.predict_async(&x, lo..lo + batch, 0).unwrap())
                        })
                        .collect();
                    for (lo, t) in tickets {
                        let got = t.wait().unwrap();
                        let want = if got.epoch % 2 == 0 { want_a } else { want_b };
                        assert_eq!(
                            &got.labels[..],
                            &want[lo..lo + batch],
                            "client {c} round {r}: epoch {} response must equal that \
                             epoch's in-memory prediction",
                            got.epoch
                        );
                        count += 1;
                    }
                }
                count
            }));
        }
        // swap back and forth underneath the live traffic: even epochs
        // serve the original model, odd epochs the rotated successor
        for swap_i in 0..4u64 {
            std::thread::sleep(Duration::from_millis(3));
            let next =
                if swap_i % 2 == 0 { successor.clone() } else { model.clone() };
            assert_eq!(handle.swap(Arc::new(next)).unwrap(), swap_i + 1);
        }
        joins.into_iter().map(|j| j.join().expect("client panicked")).sum::<usize>()
    });
    assert_eq!(handle.epoch(), 4);
    // every submitted request was answered (hot swap drops nothing)
    assert_eq!(served, clients * rounds * in_flight);
    let stats = handle.per_shard_stats();
    assert_eq!(stats.iter().map(|s| s.requests).sum::<usize>(), served);
    assert_eq!(stats.iter().map(|s| s.rows).sum::<usize>(), served * batch);
}

#[test]
fn rand_solver_model_roundtrips_bit_identical_with_provenance() {
    // a model fitted through the randomized eigensolver must persist like
    // any other — bit-identical predictions after save/load — and the
    // file must carry the solver + knobs it was fitted with
    let ds = registry::generate("moons", 400, 130);
    let cfg = PipelineConfig::builder()
        .method(Method::Nystrom)
        .l(96)
        .m(16) // m + oversample = 24 < l: the sketch path engages
        .max_iters(10)
        .workers(3)
        .block_rows(128)
        .seed(130)
        .eig_solver(EigSolver::Randomized)
        .eig_oversample(8)
        .eig_power_iters(2)
        .build()
        .unwrap();
    let (model, report) = Pipeline::with_compute(cfg, Compute::reference()).fit(&ds).unwrap();
    assert_eq!(report.eig.solver, EigSolver::Randomized);
    assert_eq!((report.eig.oversample, report.eig.power_iters), (8, 2));
    assert_eq!(model.provenance().eig, report.eig);

    let path = tmp("rand-eig");
    model.save(&path).unwrap();
    let loaded = ApncModel::load_with(&path, Compute::reference()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.provenance(), model.provenance());
    assert_eq!(loaded.provenance().eig.solver, EigSolver::Randomized);

    let fresh = registry::generate("moons", 150, 131);
    for x in [&ds.x, &fresh.x] {
        let want = model.predict_batch(x, 0).unwrap();
        for chunk in [0usize, 7, 64] {
            assert_eq!(loaded.predict_batch(x, chunk).unwrap(), want, "chunk={chunk}");
        }
    }
}

#[test]
fn v1_model_files_load_with_dense_default_provenance() {
    // a pipeline-fitted model rewritten as a version-1 file (no
    // eigensolver triple) must still load and predict identically, with
    // the provenance defaulting to the dense solver every v1 fit used
    fn fnv1a64(bytes: &[u8]) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }
    let (ds, model) = fit_model(Method::Nystrom, 132);
    assert_eq!(model.provenance().eig, EigProvenance::default(), "fixture must be dense-fitted");
    let path = tmp("v1-file");
    model.save(&path).unwrap();
    let v2 = std::fs::read(&path).unwrap();
    // magic(8) + version(4) + method(4) + kcode(4) + params(16) + d(8)
    // + k(8) + seed(8) = 60: the v2 triple lives at 60..72 — drop it,
    // stamp version 1, recompute the trailer over the hashed span
    let mut v1 = Vec::with_capacity(v2.len() - 12);
    v1.extend_from_slice(&v2[..8]);
    v1.extend_from_slice(&1u32.to_le_bytes());
    v1.extend_from_slice(&v2[12..60]);
    v1.extend_from_slice(&v2[72..]);
    let end = v1.len() - 8;
    let ck = fnv1a64(&v1[8..end]).to_le_bytes();
    v1[end..].copy_from_slice(&ck);
    std::fs::write(&path, &v1).unwrap();

    let loaded = ApncModel::load_with(&path, Compute::reference()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.provenance().eig, EigProvenance::default());
    assert_eq!(loaded.provenance(), model.provenance());
    assert_eq!(
        loaded.predict_batch(&ds.x, 0).unwrap(),
        model.predict_batch(&ds.x, 0).unwrap(),
        "a v1 file must serve the same labels"
    );
}

#[test]
fn corrupted_and_truncated_files_are_rejected() {
    let (_ds, model) = fit_model(Method::Nystrom, 107);
    let path = tmp("corrupt");
    model.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // truncations at several depths (magic, header, payload, checksum)
    for cut in [0usize, 3, 8, 24, bytes.len() / 3, bytes.len() - 1] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(
            ApncModel::load_with(&path, Compute::reference()).is_err(),
            "truncation at {cut} bytes was accepted"
        );
    }

    // single flipped bytes anywhere must be caught (checksum or header
    // validation), never silently accepted
    for pos in [8usize, 12, 40, bytes.len() / 2, bytes.len() - 4] {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x10;
        std::fs::write(&path, &corrupt).unwrap();
        assert!(
            ApncModel::load_with(&path, Compute::reference()).is_err(),
            "flipped byte at {pos} was accepted"
        );
    }

    // wrong magic
    let mut wrong = bytes.clone();
    wrong[..4].copy_from_slice(b"NOPE");
    std::fs::write(&path, &wrong).unwrap();
    let err = ApncModel::load_with(&path, Compute::reference()).unwrap_err().to_string();
    assert!(err.contains("not an APNC model"), "{err}");

    // intact bytes still load (the fixture itself is valid)
    std::fs::write(&path, &bytes).unwrap();
    assert!(ApncModel::load_with(&path, Compute::reference()).is_ok());
    std::fs::remove_file(&path).ok();
}
