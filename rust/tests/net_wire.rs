//! End-to-end coverage of the TCP serving tier: a real `NetServer` on a
//! loopback socket, driven both by the `run_loadgen` client (bit-exact
//! verification at scale, hot swap under load) and by hand-crafted raw
//! frames (out-of-order streaming, malformed wire input). The adversarial
//! cases pin the contract that bad bytes produce typed error frames and a
//! closed connection — never a panic, and never a wounded server: after
//! every attack a fresh connection must still serve verified predictions.

use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use apnc::embedding::{ApncCoeffs, CoeffBlock, Method};
use apnc::kernels::Kernel;
use apnc::model::net::{run_loadgen, LoadGenOpts, NetServer};
use apnc::model::proto::{self, Frame};
use apnc::model::serve::{AdaptiveWindow, BatchWindow, ServeCfg};
use apnc::model::shard::{Routing, ShardCfg};
use apnc::model::{ApncModel, Provenance};
use apnc::rng::Pcg;
use apnc::runtime::Compute;

/// Synthetic fitted model via the public API (random coefficients are
/// fine: the wire contract is about bytes and ordering, not accuracy).
fn synth_model(d: usize, l: usize, m: usize, k: usize, seed: u64) -> ApncModel {
    let mut rng = Pcg::seeded(seed);
    let blocks = vec![CoeffBlock {
        samples: (0..l * d).map(|_| rng.normal() as f32).collect(),
        l,
        r_t: (0..l * m).map(|_| rng.normal() as f32 * 0.2).collect(),
        m,
    }];
    let coeffs =
        ApncCoeffs { method: Method::Nystrom, d, kernel: Kernel::Rbf { gamma: 0.3 }, blocks };
    let centroids: Vec<f32> = (0..k * m).map(|_| rng.normal() as f32).collect();
    ApncModel::from_parts(
        coeffs,
        centroids,
        k,
        Provenance { dataset: "net-wire-test".into(), seed, eig: Default::default() },
        Compute::reference(),
    )
    .unwrap()
}

/// A random `(rows, d)` batch plus its in-memory oracle labels.
fn batch(model: &ApncModel, rows: usize, seed: u64) -> (Vec<f32>, Vec<u32>) {
    let mut rng = Pcg::seeded(seed);
    let x: Vec<f32> = (0..rows * model.d()).map(|_| rng.normal() as f32).collect();
    let oracle = model.predict_batch(&x, 0).unwrap();
    (x, oracle)
}

/// Connect, consume the hello frame, and sanity-check the served shape.
fn connect(addr: SocketAddr, d: usize) -> TcpStream {
    let mut s = TcpStream::connect(addr).expect("connect to the test server");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    match proto::read_frame(&mut s).expect("read the hello frame") {
        Some(Frame::Hello { d: hd, .. }) => assert_eq!(hd as usize, d),
        other => panic!("expected a hello frame, got {other:?}"),
    }
    s
}

fn predict_frame(id: u64, x: &[f32], d: usize) -> Frame {
    Frame::Predict { id, rows: (x.len() / d) as u32, x: x.to_vec() }
}

fn read_labels(s: &mut TcpStream) -> (u64, u64, Vec<u32>) {
    match proto::read_frame(s).expect("read a response frame") {
        Some(Frame::Labels { id, epoch, labels }) => (id, epoch, labels),
        other => panic!("expected a labels frame, got {other:?}"),
    }
}

fn read_error(s: &mut TcpStream) -> (u64, String) {
    match proto::read_frame(s).expect("read a response frame") {
        Some(Frame::Error { id, message }) => (id, message),
        other => panic!("expected an error frame, got {other:?}"),
    }
}

#[test]
fn loadgen_closed_loop_is_bit_identical_over_tcp() {
    let d = 16;
    let model = synth_model(d, 128, 64, 10, 501);
    let (x, oracle) = batch(&model, 256, 502);
    let cfg = ShardCfg {
        shards: 4,
        serve: ServeCfg {
            window: BatchWindow::new(128, Duration::from_micros(200)),
            queue_limit: 0,
            adaptive: Some(AdaptiveWindow::new(
                Duration::from_micros(50),
                Duration::from_micros(2000),
            )),
        },
        routing: Routing::LeastLoaded,
    };
    let handle = model.serve_tuned(cfg).unwrap();
    let server = NetServer::bind("127.0.0.1:0", handle.clone()).unwrap();
    let report = run_loadgen(
        &server.local_addr().to_string(),
        &x,
        d,
        &oracle,
        LoadGenOpts { connections: 8, requests: 64, rows_per_request: 16, ..Default::default() },
    )
    .unwrap();
    assert_eq!(report.dropped, 0, "no request may go unanswered");
    assert_eq!(report.mismatches, 0, "every response must match the in-memory oracle");
    assert_eq!(report.rows, 64 * 16, "every row of every response verified");
    assert_eq!(report.epochs, vec![0], "no swap happened, so one epoch");
    assert!(report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);
    server.shutdown();
    handle.shutdown();
}

#[test]
fn hot_swap_mid_drive_keeps_every_response_verified() {
    let d = 16;
    let model = synth_model(d, 128, 64, 10, 511);
    let (x, oracle) = batch(&model, 192, 512);
    // the replacement is a clone of the serving model: the oracle stays
    // valid across the swap while the epoch tag proves it happened
    let replacement = Arc::new(model.clone());
    let canary = x[..8 * d].to_vec();
    let handle = model.serve_tuned(ShardCfg { shards: 2, ..Default::default() }).unwrap();
    let server = NetServer::bind("127.0.0.1:0", handle.clone()).unwrap();
    let addr = server.local_addr().to_string();
    let swapper = {
        let handle = handle.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            handle.swap_warm(replacement, &canary).expect("warm swap under load")
        })
    };
    // open loop: ~500 ms of paced traffic, so the 100 ms swap lands with
    // requests in flight on both sides of it
    let report = run_loadgen(
        &addr,
        &x,
        d,
        &oracle,
        LoadGenOpts {
            connections: 4,
            requests: 300,
            rows_per_request: 16,
            rps: 600,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(swapper.join().expect("swap thread"), 1, "the swap publishes epoch 1");
    assert_eq!(report.dropped, 0, "the swap must not drop a single request");
    assert_eq!(report.mismatches, 0, "responses stay bit-identical across the swap");
    assert!(
        report.epochs.len() >= 2,
        "expected responses from both epochs, saw {:?}",
        report.epochs
    );
    server.shutdown();
    handle.shutdown();
}

#[test]
fn responses_stream_out_of_order_across_connections() {
    let d = 16;
    let model = synth_model(d, 64, 32, 8, 521);
    let (x, oracle) = batch(&model, 16, 522);
    let handle = model.serve_tuned(ShardCfg { shards: 2, ..Default::default() }).unwrap();
    let server = NetServer::bind("127.0.0.1:0", handle.clone()).unwrap();
    let addr = server.local_addr();
    // park shard 0: the stall is itself a queue item, so the next request
    // routed there waits ~300 ms behind it
    handle.shard(0).inject_stall(Duration::from_millis(300));
    let mut a = connect(addr, d);
    // round-robin: id 1 -> shard 0 (stalled), id 2 -> shard 1 (fast)
    proto::write_frame(&mut a, &predict_frame(1, &x[..4 * d], d)).unwrap();
    proto::write_frame(&mut a, &predict_frame(2, &x[4 * d..8 * d], d)).unwrap();
    // id 2 overtaking id 1 proves out-of-order streaming on one socket —
    // and that both of a's requests are routed before b submits anything
    let (id, _, labels) = read_labels(&mut a);
    assert_eq!(id, 2, "the fast shard's response must overtake the stalled one");
    assert_eq!(&labels[..], &oracle[4..8]);
    // a second connection interleaves while a's id 1 is still in flight
    let mut b = connect(addr, d);
    proto::write_frame(&mut b, &predict_frame(1, &x[8 * d..12 * d], d)).unwrap();
    proto::write_frame(&mut b, &predict_frame(2, &x[12 * d..16 * d], d)).unwrap();
    let (id, _, labels) = read_labels(&mut b);
    assert_eq!(id, 2, "b's fast response overtakes its own stalled request too");
    assert_eq!(&labels[..], &oracle[12..16]);
    let (id, _, labels) = read_labels(&mut b);
    assert_eq!(id, 1);
    assert_eq!(&labels[..], &oracle[8..12]);
    let (id, _, labels) = read_labels(&mut a);
    assert_eq!(id, 1);
    assert_eq!(&labels[..], &oracle[..4]);
    server.shutdown();
    handle.shutdown();
}

#[test]
fn malformed_wire_input_gets_typed_errors_and_never_kills_the_server() {
    let d = 16;
    let model = synth_model(d, 64, 32, 8, 531);
    let (x, oracle) = batch(&model, 32, 532);
    let handle = model.serve_tuned(ShardCfg::default()).unwrap();
    let server = NetServer::bind("127.0.0.1:0", handle.clone()).unwrap();
    let addr = server.local_addr();

    // request-scoped: a shape mismatch answers with a typed error frame
    // carrying the request id, and the connection keeps serving
    let mut s = connect(addr, d);
    proto::write_frame(&mut s, &Frame::Predict { id: 9, rows: 3, x: x[..5 * d].to_vec() })
        .unwrap();
    let (id, why) = read_error(&mut s);
    assert_eq!(id, 9);
    assert!(why.contains("shape mismatch"), "{why}");
    proto::write_frame(&mut s, &predict_frame(10, &x[..4 * d], d)).unwrap();
    let (id, _, labels) = read_labels(&mut s);
    assert_eq!(id, 10, "the connection must survive a request-scoped rejection");
    assert_eq!(&labels[..], &oracle[..4]);
    drop(s);

    // connection-fatal: wrong magic
    let mut s = connect(addr, d);
    s.write_all(b"NOPE").unwrap();
    let (_, why) = read_error(&mut s);
    assert!(why.contains("magic"), "{why}");
    assert_eq!(
        proto::read_frame(&mut s).unwrap(),
        None,
        "the server closes the connection after a framing error"
    );

    // connection-fatal: future protocol version (checked before payload)
    let mut s = connect(addr, d);
    let mut raw = Vec::new();
    raw.extend_from_slice(&proto::MAGIC);
    raw.extend_from_slice(&99u32.to_le_bytes());
    raw.extend_from_slice(&2u32.to_le_bytes());
    raw.extend_from_slice(&1u64.to_le_bytes());
    raw.extend_from_slice(&0u32.to_le_bytes());
    s.write_all(&raw).unwrap();
    let (_, why) = read_error(&mut s);
    assert!(why.contains("version"), "{why}");

    // connection-fatal: an absurd declared payload length must be refused
    // up front — the server must not allocate 4 GiB on a liar's say-so
    let mut s = connect(addr, d);
    let mut raw = Vec::new();
    raw.extend_from_slice(&proto::MAGIC);
    raw.extend_from_slice(&proto::VERSION.to_le_bytes());
    raw.extend_from_slice(&2u32.to_le_bytes());
    raw.extend_from_slice(&1u64.to_le_bytes());
    raw.extend_from_slice(&u32::MAX.to_le_bytes());
    s.write_all(&raw).unwrap();
    let (_, why) = read_error(&mut s);
    assert!(why.contains("exceeds"), "{why}");

    // connection-fatal: one flipped checksum byte
    let mut s = connect(addr, d);
    let mut raw = Vec::new();
    proto::write_frame(&mut raw, &predict_frame(3, &x[..2 * d], d)).unwrap();
    let last = raw.len() - 1;
    raw[last] ^= 0x40;
    s.write_all(&raw).unwrap();
    let (_, why) = read_error(&mut s);
    assert!(why.contains("checksum"), "{why}");

    // connection-fatal: a frame cut short by a write-side shutdown
    let mut s = connect(addr, d);
    let mut raw = Vec::new();
    proto::write_frame(&mut raw, &predict_frame(4, &x[..2 * d], d)).unwrap();
    s.write_all(&raw[..raw.len() / 2]).unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let (_, why) = read_error(&mut s);
    assert!(why.contains("truncated"), "{why}");

    // mid-payload disconnect: drop the socket halfway through a frame,
    // then prove the tier still serves — no thread died with it
    let mut s = connect(addr, d);
    let mut raw = Vec::new();
    proto::write_frame(&mut raw, &predict_frame(5, &x[..2 * d], d)).unwrap();
    s.write_all(&raw[..raw.len() / 2]).unwrap();
    drop(s);
    let mut s = connect(addr, d);
    proto::write_frame(&mut s, &predict_frame(6, &x[..4 * d], d)).unwrap();
    let (id, _, labels) = read_labels(&mut s);
    assert_eq!(id, 6, "a fresh connection must serve after every attack");
    assert_eq!(&labels[..], &oracle[..4]);
    server.shutdown();
    handle.shutdown();
}
