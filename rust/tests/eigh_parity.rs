//! Determinism + pool-lifecycle contract for the parallel `eigh` and the
//! persistent worker pool (parallel substrate v2).
//!
//! (1) `linalg::eigh` must be **bit-identical** across thread counts —
//! its Householder panels, Q accumulation, and QL rotation batches all
//! reduce in fixed chunk order. (2) The pool must be *reused* across
//! repeated `eigh`/`gram` calls (jobs flow, worker set stays bounded)
//! while staying deterministic. (3) Reference/kernel ops invoked from
//! multi-worker MapReduce map tasks must run under the
//! nested-parallelism guard: sequential (`max_threads() == 1`), same
//! bytes, no deadlock against the single-job pool.
//!
//! NOTE on the global thread override: `parallel::set_threads` is
//! process-wide, so every test that flips it serializes on
//! `THREADS_LOCK`. Tests that only rely on the guard (which pins the
//! thread count regardless of the override) don't need the lock.

use std::sync::Mutex;

use apnc::kernels::Kernel;
use apnc::linalg::{eigh, Eigh, Matrix};
use apnc::mapreduce::{Engine, EngineConfig};
use apnc::parallel;
use apnc::rng::Pcg;

static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn random_spd(n: usize, seed: u64) -> Matrix {
    let mut rng = Pcg::seeded(seed);
    let b = Matrix::from_fn(n, n, |_, _| rng.normal());
    let mut a = b.matmul_nt(&b);
    for i in 0..n {
        a[(i, i)] += 1.0;
    }
    a
}

fn eigh_bits(e: &Eigh) -> (Vec<u64>, Vec<u64>) {
    (
        e.values.iter().map(|v| v.to_bits()).collect(),
        e.vectors.data().iter().map(|v| v.to_bits()).collect(),
    )
}

#[test]
fn eigh_bit_identical_across_thread_counts() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    // n large enough that tred2's panels, the Q accumulation, and tql2's
    // rotation batches all span several chunks (the parallel path must
    // actually engage for threads > 1)
    let a = random_spd(768, 7001);
    let run = |threads: usize| {
        parallel::set_threads(threads);
        let e = eigh(&a);
        parallel::set_threads(0);
        e
    };
    let base = eigh_bits(&run(1));
    for t in [2, 7, 8] {
        let got = eigh_bits(&run(t));
        assert_eq!(got.0, base.0, "eigenvalues differ, threads={t}");
        assert_eq!(got.1, base.1, "eigenvectors differ, threads={t}");
    }
}

#[test]
fn pool_survives_repeated_eigh_and_gram_calls() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    parallel::set_threads(4);
    let a = random_spd(768, 7002);
    let mut rng = Pcg::seeded(7003);
    let pts: Vec<f32> = (0..512 * 8).map(|_| rng.normal() as f32).collect();
    let kernel = Kernel::Rbf { gamma: 0.2 };

    let e0 = eigh_bits(&eigh(&a));
    let g0: Vec<u64> = kernel.gram(&pts, 8).data().iter().map(|v| v.to_bits()).collect();
    let warm = parallel::pool_stats();
    assert!(warm.jobs_run > 0, "sized to engage the pool at 4 threads");
    assert!(warm.workers_spawned >= 1);

    // repeated calls reuse the pool (no per-call spawn) and stay
    // bit-deterministic, also when the thread count changes in between
    for t in [4usize, 2, 7, 4] {
        parallel::set_threads(t);
        let e = eigh_bits(&eigh(&a));
        assert_eq!(e, e0, "eigh drifted on reuse, threads={t}");
        let g: Vec<u64> = kernel.gram(&pts, 8).data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(g, g0, "gram drifted on reuse, threads={t}");
    }
    let after = parallel::pool_stats();
    assert!(after.jobs_run > warm.jobs_run, "jobs must flow through the persistent pool");
    assert!(
        after.workers_spawned <= warm.workers_spawned.max(6),
        "pool grew past what 7 threads need: {} -> {}",
        warm.workers_spawned,
        after.workers_spawned
    );
    parallel::set_threads(0);
}

#[test]
fn nested_engine_worker_calls_are_guarded_and_deterministic() {
    // map tasks big enough that gram would fan out if unguarded; with
    // several engine workers the guard must pin them to one thread, the
    // job must complete (no deadlock against the single-job pool), and
    // the bytes must match a single-worker run
    let mut rng = Pcg::seeded(7004);
    let blocks: Vec<Vec<f32>> = (0..6)
        .map(|_| (0..300 * 6).map(|_| rng.normal() as f32).collect())
        .collect();
    let kernel = Kernel::Rbf { gamma: 0.4 };
    let run = |workers: usize| {
        let engine = Engine::new(EngineConfig::with_workers(workers));
        engine.run_map(&blocks, |_, block: &Vec<f32>, _ctx| {
            let g = kernel.gram(block, 6);
            let checksum: f64 = g.data().iter().sum();
            (parallel::max_threads(), checksum.to_bits())
        })
        .unwrap()
    };
    let multi = run(4);
    for (i, (threads_seen, _)) in multi.outputs.iter().enumerate() {
        assert_eq!(*threads_seen, 1, "map task {i} not guarded under 4 workers");
    }
    let single = run(1);
    let multi_sums: Vec<u64> = multi.outputs.iter().map(|(_, s)| *s).collect();
    let single_sums: Vec<u64> = single.outputs.iter().map(|(_, s)| *s).collect();
    assert_eq!(multi_sums, single_sums, "guarded vs unguarded bytes differ");
}

#[test]
fn single_reducer_keeps_the_pool() {
    // the Property-4.3 coefficient reducer is the one task allowed to fan
    // out: with a single reduce group the engine must NOT guard it
    use apnc::mapreduce::{Emitter, Job, TaskCtx};
    struct OneGroup;
    impl Job for OneGroup {
        type Input = u32;
        type Key = u8;
        type Value = u32;
        type Output = usize;
        fn map(&self, _id: usize, input: &u32, _ctx: &mut TaskCtx, emit: &mut Emitter<u8, u32>) {
            emit.emit(0, *input);
        }
        fn reduce(&self, _key: u8, _values: Vec<u32>, _ctx: &mut TaskCtx) -> usize {
            // not wrapped in sequential_scope => sees the global setting
            apnc::parallel::max_threads()
        }
    }
    let _g = THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    parallel::set_threads(5);
    let run = Engine::new(EngineConfig::with_workers(4)).run(&OneGroup, &[1u32, 2, 3, 4]).unwrap();
    parallel::set_threads(0);
    assert_eq!(run.outputs, vec![5], "lone reducer must keep full pool access");
}
