//! Property-based tests over the coordinator invariants, using the
//! in-tree prop harness (no proptest in this container).
//!
//! Each property is checked across randomized shapes/seeds; a failing
//! seed is printed for deterministic replay.

use apnc::coordinator::cluster_job::{self, ClusterConfig};
use apnc::coordinator::driver::{Pipeline, PipelineConfig};
use apnc::coordinator::embed_job;
use apnc::coordinator::sample::{self, SampleMode};
use apnc::coordinator::DataBlock;
use apnc::data::synth;
use apnc::embedding::{nystrom, stable, Method};
use apnc::kernels::Kernel;
use apnc::mapreduce::{Engine, EngineConfig, FaultPlan};
use apnc::prop::{check, sized};
use apnc::rng::Pcg;
use apnc::runtime::{Compute, DistKind};

fn random_blocks(rng: &mut Pcg, n: usize, d: usize, block_rows: usize) -> Vec<DataBlock> {
    let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    DataBlock::partition(&x, n, d, block_rows)
}

/// Property: embedding job output is invariant to worker count AND block
/// size never changes per-point values (only their grouping).
#[test]
fn prop_embed_job_schedule_invariant() {
    check("embed-schedule-invariant", 0xE1, 8, |rng, case| {
        let n = sized(rng, case, 8, 40, 200);
        let d = sized(rng, case, 8, 2, 12);
        let l = sized(rng, case, 8, 4, 16);
        let m = sized(rng, case, 8, 2, 10);
        let samples: Vec<f32> = (0..l * d).map(|_| rng.normal() as f32).collect();
        let coeffs = nystrom::fit(&samples, d, Kernel::Rbf { gamma: 0.3 }, m);
        let x: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
        let compute = Compute::reference();
        let mut flat: Option<Vec<f32>> = None;
        for (workers, block_rows) in [(1usize, 16usize), (7, 16), (3, 64)] {
            let blocks = DataBlock::partition(&x, n, d, block_rows);
            let engine = Engine::new(EngineConfig::with_workers(workers));
            let out = embed_job::run(&engine, &compute, &coeffs, &blocks).unwrap();
            let mut y = Vec::new();
            for b in &out.blocks {
                y.extend_from_slice(&b.x);
            }
            match &flat {
                None => flat = Some(y),
                Some(want) => {
                    assert_eq!(want.len(), y.len());
                    for (a, b) in want.iter().zip(&y) {
                        assert!((a - b).abs() < 1e-5, "embedding differs across schedules");
                    }
                }
            }
        }
    });
}

/// Property: the sampling job is schedule-invariant and its output size
/// concentrates near l (Bernoulli) or is exactly l (Exact).
#[test]
fn prop_sample_modes() {
    check("sample-modes", 0x5A, 10, |rng, case| {
        let n = sized(rng, case, 10, 200, 3000);
        let d = sized(rng, case, 10, 1, 8);
        let l = sized(rng, case, 10, 10, n / 4);
        let blocks = random_blocks(rng, n, d, 128);
        let engine = Engine::new(EngineConfig::with_workers(4));
        let exact = sample::run(&engine, &blocks, d, n, l, SampleMode::Exact).unwrap();
        assert_eq!(exact.indices.len(), l.max(1));
        // indices unique + within range
        let mut sorted = exact.indices.clone();
        sorted.dedup();
        assert_eq!(sorted.len(), exact.indices.len());
        assert!(exact.indices.iter().all(|&i| (i as usize) < n));
        let bern = sample::run(&engine, &blocks, d, n, l, SampleMode::Bernoulli).unwrap();
        // 6-sigma band around the binomial mean
        let mean = l as f64;
        let sd = (l as f64).sqrt().max(1.0);
        assert!(
            (bern.indices.len() as f64 - mean).abs() < 6.0 * sd + 3.0,
            "bernoulli sample size {} far from l {}",
            bern.indices.len(),
            l
        );
    });
}

/// Property: the Lloyd objective is monotone non-increasing under l2^2
/// (mean updates are optimal). Under l1 (APNC-SD) the paper's algorithm
/// still uses *mean* updates — Property 4.1 requires linear averaging —
/// which does not minimize the l1 objective, so only overall improvement
/// and small per-step slack can be asserted.
#[test]
fn prop_lloyd_objective_monotone() {
    check("lloyd-monotone", 0x10, 8, |rng, case| {
        let n = sized(rng, case, 8, 60, 400);
        let m = sized(rng, case, 8, 2, 12);
        let k = sized(rng, case, 8, 2, 6).min(n / 4);
        let workers = 1 + rng.below(6);
        let blocks = random_blocks(rng, n, m, 64);
        let engine = Engine::new(EngineConfig::with_workers(workers));
        let dist = if rng.bernoulli(0.5) { DistKind::L2Sq } else { DistKind::L1 };
        let out = cluster_job::run(
            &engine,
            &Compute::reference(),
            &blocks,
            m,
            dist,
            &ClusterConfig {
                k,
                max_iters: 8,
                tol: 0.0,
                seed: rng.next_u64(),
                ..Default::default()
            },
        )
        .unwrap();
        let slack = match dist {
            DistKind::L2Sq => 1e-5,
            DistKind::L1 => 0.02, // mean-update under l1: small rises happen
        };
        for w in out.obj_curve.windows(2) {
            assert!(
                w[1] <= w[0] * (1.0 + slack) + 1e-6,
                "objective rose under {dist:?}: {:?}",
                out.obj_curve
            );
        }
        if out.obj_curve.len() >= 3 {
            let first = out.obj_curve[0];
            let last = *out.obj_curve.last().unwrap();
            assert!(last <= first * (1.0 + 1e-9), "no overall improvement: {:?}", out.obj_curve);
        }
        // counts conserved: sum over clusters equals n
        let mut counts = vec![0usize; k];
        for &lab in &out.labels {
            counts[lab as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), n);
    });
}

/// Property 4.1 on the fitted coefficients (both methods): the embedding
/// of a uniform mixture equals the mixture of embeddings.
#[test]
fn prop_linearity_of_fitted_embeddings() {
    check("apnc-linearity", 0x41, 8, |rng, case| {
        let d = sized(rng, case, 8, 2, 10);
        let l = sized(rng, case, 8, 6, 24);
        let m = sized(rng, case, 8, 2, 12);
        let samples: Vec<f32> = (0..l * d).map(|_| rng.normal() as f32).collect();
        let kernel = Kernel::Rbf { gamma: 0.25 };
        let coeffs = if rng.bernoulli(0.5) {
            nystrom::fit(&samples, d, kernel, m)
        } else {
            stable::fit(&samples, d, kernel, m, (l * 2) / 5 + 1, rng)
        };
        // two points; linearity: the average of their embeddings equals the
        // embedding induced by the average of their kernel columns
        let ab: Vec<f32> = (0..2 * d).map(|_| rng.normal() as f32).collect();
        let compute = Compute::reference();
        let y = coeffs.embed_block(&compute, &ab, 2).unwrap();
        let mm = coeffs.m();
        let blk = &coeffs.blocks[0];
        let kb = compute.kmat(&ab, 2, d, &blk.samples, blk.l, kernel).unwrap();
        for j in 0..blk.m {
            let avg_col: f64 = (0..blk.l)
                .map(|i| 0.5 * (kb[i] + kb[blk.l + i]) as f64 * blk.r_t[i * blk.m + j] as f64)
                .sum();
            let avg_y = 0.5 * (y[j] as f64 + y[mm + j] as f64);
            assert!(
                (avg_col - avg_y).abs() < 1e-4 * (1.0 + avg_y.abs()),
                "linearity violated at dim {j}: {avg_col} vs {avg_y}"
            );
        }
    });
}

/// Property: pipeline output labels are a valid clustering (right length,
/// k respected) and deterministic under fault injection.
#[test]
fn prop_pipeline_fault_determinism() {
    check("pipeline-fault-determinism", 0xFA, 4, |rng, case| {
        let n = sized(rng, case, 4, 300, 800);
        let ds =
            synth::gaussian_manifold("p", n, 6, 3, 3, 0.4, 0.2, synth::Warp::Tanh, rng.next_u64());
        let base = PipelineConfig {
            method: if rng.bernoulli(0.5) { Method::Nystrom } else { Method::StableDist },
            l: 32,
            m: 24,
            workers: 3,
            block_rows: 64,
            max_iters: 6,
            kernel: Some(Kernel::Rbf { gamma: 0.2 }),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let clean = Pipeline::with_compute(base.clone(), Compute::reference()).run(&ds).unwrap();
        assert_eq!(clean.labels.len(), n);
        assert!(clean.labels.iter().all(|&c| (c as usize) < 3));
        let mut faulty_cfg = base;
        faulty_cfg.faults = FaultPlan::with_map_failures(0.25, rng.next_u64());
        let faulty = Pipeline::with_compute(faulty_cfg, Compute::reference()).run(&ds).unwrap();
        assert_eq!(clean.labels, faulty.labels, "faults changed the output");
    });
}
