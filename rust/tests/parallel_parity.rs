//! Parity + determinism for the parallel tiled compute core.
//!
//! (1) The GEMM-formulated kernel blocks (`Kernel::{block, gram}`, f64;
//! `runtime::reference::kmat`, f32) must match the scalar `Kernel::eval`
//! loop for every kernel kind. (2) Pipeline outputs and the parallel
//! linalg primitives must be **bit-identical** across thread counts —
//! the parallel core's schedule-independence contract.
//!
//! NOTE on the global thread override: `parallel::set_threads` is
//! process-wide, and the test harness runs these tests concurrently. The
//! parallel core is deterministic *by design* for any thread count, so
//! tests racing on the override still assert correctly — a failure here
//! means the determinism contract itself is broken.

use apnc::coordinator::driver::{Pipeline, PipelineConfig};
use apnc::data::registry;
use apnc::embedding::Method;
use apnc::kernels::Kernel;
use apnc::linalg::Matrix;
use apnc::parallel;
use apnc::rng::Pcg;
use apnc::runtime::{reference, Compute};

fn all_kernels() -> [Kernel; 4] {
    [
        Kernel::Linear,
        Kernel::Rbf { gamma: 0.2 },
        Kernel::Poly { c: 1.0, degree: 3.0 },
        Kernel::Tanh { a: 0.0045, b: 0.11 },
    ]
}

fn randv(rng: &mut Pcg, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

#[test]
fn gemm_block_matches_scalar_eval_f64() {
    let mut rng = Pcg::seeded(2001);
    // awkward sizes: not tile multiples, d not a multiple of 4
    let (na, nb, d) = (37, 23, 7);
    let a = randv(&mut rng, na * d);
    let b = randv(&mut rng, nb * d);
    for kernel in all_kernels() {
        let blk = kernel.block(&a, &b, d);
        assert_eq!(blk.shape(), (na, nb));
        for i in 0..na {
            for j in 0..nb {
                let want = kernel.eval(&a[i * d..(i + 1) * d], &b[j * d..(j + 1) * d]);
                let got = blk[(i, j)];
                assert!(
                    (got - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "{kernel:?} ({i},{j}): got {got}, want {want}"
                );
            }
        }
    }
}

#[test]
fn gemm_gram_matches_scalar_eval_and_block_bitwise() {
    let mut rng = Pcg::seeded(2002);
    let (n, d) = (41, 6);
    let a = randv(&mut rng, n * d);
    for kernel in all_kernels() {
        let g = kernel.gram(&a, d);
        for i in 0..n {
            for j in 0..n {
                let want = kernel.eval(&a[i * d..(i + 1) * d], &a[j * d..(j + 1) * d]);
                assert!(
                    (g[(i, j)] - want).abs() <= 1e-12 * want.abs().max(1.0),
                    "{kernel:?} ({i},{j})"
                );
                // mirror is an exact copy
                assert_eq!(g[(i, j)], g[(j, i)], "{kernel:?} symmetry ({i},{j})");
            }
        }
        // triangular+mirror gram and the full GEMM block share the dot
        // kernel, so they agree to the bit
        let b = kernel.block(&a, &a, d);
        assert_eq!(g, b, "{kernel:?} gram != block(a, a)");
    }
}

#[test]
fn reference_kmat_matches_scalar_eval_f32() {
    let mut rng = Pcg::seeded(2003);
    let (rows, l, d) = (29, 13, 9);
    let x = randv(&mut rng, rows * d);
    let s = randv(&mut rng, l * d);
    for kernel in all_kernels() {
        let got = reference::kmat(&x, rows, d, &s, l, kernel);
        for r in 0..rows {
            for j in 0..l {
                let want =
                    kernel.eval(&x[r * d..(r + 1) * d], &s[j * d..(j + 1) * d]) as f32;
                let diff = (got[r * l + j] - want).abs();
                assert!(
                    diff <= 1e-5 * want.abs().max(1.0),
                    "{kernel:?} ({r},{j}): got {}, want {want}",
                    got[r * l + j]
                );
            }
        }
    }
}

#[test]
fn linalg_bit_identical_across_thread_counts() {
    // sizes chosen so chunk_rows yields several chunks per op — the
    // parallel path must actually engage for threads > 1
    let mut rng = Pcg::seeded(2004);
    let a = Matrix::from_fn(301, 200, |_, _| rng.normal());
    let b = Matrix::from_fn(200, 153, |_, _| rng.normal());
    let c = Matrix::from_fn(181, 200, |_, _| rng.normal());
    let pts = randv(&mut rng, 401 * 6);
    let run = |threads: usize| {
        parallel::set_threads(threads);
        let out = (
            a.matmul(&b),
            a.matmul_nt(&c),
            a.transpose(),
            Kernel::Rbf { gamma: 0.3 }.gram(&pts, 6),
        );
        parallel::set_threads(0);
        out
    };
    let base = run(1);
    for t in [2, 8] {
        let got = run(t);
        assert_eq!(got.0, base.0, "matmul, threads={t}");
        assert_eq!(got.1, base.1, "matmul_nt, threads={t}");
        assert_eq!(got.2, base.2, "transpose, threads={t}");
        assert_eq!(got.3, base.3, "gram, threads={t}");
    }
}

#[test]
fn pipeline_bit_identical_across_thread_counts() {
    // operating point sized so the embed/assign inner loops span several
    // parallel chunks per block (not just engine-level block parallelism)
    let ds = registry::generate("covtype", 4000, 21);
    let run_with = |threads: usize| {
        let cfg = PipelineConfig {
            method: Method::Nystrom,
            l: 128,
            m: 96,
            max_iters: 6,
            workers: 3,
            threads,
            block_rows: 1024,
            seed: 4242,
            ..Default::default()
        };
        Pipeline::with_compute(cfg, Compute::reference()).run(&ds).unwrap()
    };
    let base = run_with(1);
    for t in [2, 8] {
        let out = run_with(t);
        assert_eq!(out.labels, base.labels, "labels, threads={t}");
        assert_eq!(out.obj_curve, base.obj_curve, "objective curve, threads={t}");
        assert_eq!(out.nmi.to_bits(), base.nmi.to_bits(), "nmi, threads={t}");
        assert_eq!(out.l_actual, base.l_actual);
        assert_eq!(out.m_actual, base.m_actual);
    }
    parallel::set_threads(0);
}

#[test]
fn reference_assign_tiled_merge_is_deterministic() {
    // rows large enough to span several tiles; partial (Z, g, obj) merge
    // order must not depend on the thread count
    let mut rng = Pcg::seeded(2005);
    // rows >> chunk_rows(rows, k*m) = 256k/40, so the merge spans >= 4 tiles
    let (rows, m, k) = (30_000, 8, 5);
    let y = randv(&mut rng, rows * m);
    let centroids = y[..k * m].to_vec();
    let mask = vec![1.0f32; rows];
    let run = |threads: usize| {
        parallel::set_threads(threads);
        let out =
            reference::assign(&y, rows, m, &centroids, k, &mask, apnc::runtime::DistKind::L2Sq);
        parallel::set_threads(0);
        out
    };
    let base = run(1);
    for t in [2, 8] {
        let got = run(t);
        assert_eq!(got.assign, base.assign, "threads={t}");
        assert_eq!(got.z, base.z, "threads={t}");
        assert_eq!(got.g, base.g, "threads={t}");
        assert_eq!(got.obj.to_bits(), base.obj.to_bits(), "threads={t}");
    }
}
