//! End-to-end integration: the full pipeline on the PJRT artifact backend
//! (the production path), plus PJRT-vs-reference pipeline agreement and a
//! tiny-scale run of each table harness.
//!
//! PJRT-dependent tests skip with a notice when `make artifacts` hasn't run.

use apnc::coordinator::driver::{Pipeline, PipelineConfig};
use apnc::coordinator::sample::SampleMode;
use apnc::data::registry;
use apnc::embedding::Method;
use apnc::experiments::{table2, table3};
use apnc::linalg::EigSolver;
use apnc::runtime::Compute;

fn pjrt_or_skip() -> Option<Compute> {
    let dir = Compute::default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    Some(Compute::pjrt(&dir).expect("pjrt backend"))
}

fn cfg(method: Method) -> PipelineConfig {
    PipelineConfig {
        method,
        // m < l: the whitened Nyström embedding must truncate the noise
        // directions (lambda^{-1/2} amplifies the smallest eigenvalues)
        l: 128,
        m: 64,
        workers: 4,
        max_iters: 15,
        // kpp can seed both centroids in one ring; restarts make the good
        // optimum (which has a much lower objective) win deterministically
        restarts: 5,
        sample_mode: SampleMode::Exact,
        seed: 1234,
        ..Default::default()
    }
}

#[test]
fn pjrt_pipeline_clusters_rings() {
    let Some(pjrt) = pjrt_or_skip() else { return };
    let ds = registry::generate("rings", 1200, 3);
    let out = Pipeline::with_compute(cfg(Method::Nystrom), pjrt).run(&ds).unwrap();
    assert!(out.nmi > 0.8, "rings nmi on pjrt = {}", out.nmi);
    assert_eq!(out.labels.len(), ds.n);
    assert_eq!(out.embed_metrics.shuffle_bytes, 0);
}

#[test]
fn pjrt_and_reference_pipelines_agree() {
    // same seeds, same data: label assignments must match across backends
    // (the HLO path and the rust path compute the same math in f32)
    let Some(pjrt) = pjrt_or_skip() else { return };
    let ds = registry::generate("moons", 800, 5);
    let a = Pipeline::with_compute(cfg(Method::Nystrom), pjrt).run(&ds).unwrap();
    let b = Pipeline::with_compute(cfg(Method::Nystrom), Compute::reference()).run(&ds).unwrap();
    // f32 rounding at padded vs unpadded shapes can flip borderline points;
    // demand near-identical agreement rather than bit equality
    let disagree = a
        .labels
        .iter()
        .zip(&b.labels)
        .filter(|(x, y)| x != y)
        .count();
    let frac = disagree as f64 / ds.n as f64;
    assert!(
        frac < 0.02 || (apnc::metrics::nmi(&a.labels, &b.labels) > 0.95),
        "backends disagree on {disagree}/{} points",
        ds.n
    );
    assert!((a.nmi - b.nmi).abs() < 0.05, "nmi gap: {} vs {}", a.nmi, b.nmi);
}

#[test]
fn pjrt_stable_dist_works() {
    // covtype-like folded manifold: the workload where the paper's Table 3
    // shows APNC-SD at its strongest (rings favor the Nystrom whitening)
    let Some(pjrt) = pjrt_or_skip() else { return };
    let ds = registry::generate("covtype", 3000, 9);
    let mut c = cfg(Method::StableDist);
    c.m = 192;
    let out = Pipeline::with_compute(c, pjrt).run(&ds).unwrap();
    assert!(out.nmi > 0.5, "sd covtype nmi on pjrt = {}", out.nmi);
    assert_eq!(out.m_actual, 192);
}

#[test]
fn table2_tiny_on_pjrt() {
    let Some(pjrt) = pjrt_or_skip() else { return };
    let cfg = table2::Table2Config {
        runs: 1,
        scale: 0.02,
        l_values: vec![24],
        m: 48,
        fourier_features: 32,
        seed: 3,
        only: Some("pie".into()),
    };
    let tables = table2::run(&cfg, &pjrt).unwrap();
    assert_eq!(tables.len(), 1);
    assert_eq!(tables[0].methods.len(), 5); // all five on an RBF dataset
    for row in &tables[0].cells {
        assert!(row[0].scores.iter().all(|s| (0.0..=1.0).contains(s)));
    }
}

#[test]
fn table3_tiny_on_pjrt() {
    let Some(pjrt) = pjrt_or_skip() else { return };
    let cfg = table3::Table3Config {
        runs: 1,
        scale: 0.01,
        l_values: vec![48],
        m: 64,
        nodes: 4,
        max_iters: 3,
        seed: 4,
        only: Some("rcv1".into()),
    };
    let tables = table3::run(&cfg, &pjrt).unwrap();
    assert_eq!(tables.len(), 1);
    assert!(tables[0].cells[1][0].embed_secs[0] > 0.0);
}

#[test]
fn randomized_eigensolver_matches_dense_clustering_quality() {
    // PR-7 quality pin: swapping the whitening eigensolver for the
    // randomized truncated one at equal (l, m) must not cost clustering
    // quality — NMI within 0.02 of the dense fit (reference backend, so
    // this runs everywhere)
    let ds = registry::generate("rings", 1200, 3);
    let mut dense_cfg = cfg(Method::Nystrom);
    dense_cfg.m = 32;
    dense_cfg.eig_solver = EigSolver::Dense;
    let mut rand_cfg = dense_cfg.clone();
    rand_cfg.eig_solver = EigSolver::Randomized; // m + 8 = 40 < l = 128
    let dense = Pipeline::with_compute(dense_cfg, Compute::reference()).run(&ds).unwrap();
    let rand = Pipeline::with_compute(rand_cfg.clone(), Compute::reference()).run(&ds).unwrap();
    assert!(dense.nmi > 0.8, "dense baseline degenerated: nmi {}", dense.nmi);
    assert!(
        (dense.nmi - rand.nmi).abs() <= 0.02,
        "rand solver cost quality: dense nmi {} vs rand nmi {}",
        dense.nmi,
        rand.nmi
    );
    // and the fit really did go through the randomized path
    let (model, report) =
        Pipeline::with_compute(rand_cfg, Compute::reference()).fit(&ds).unwrap();
    assert_eq!(report.eig.solver, EigSolver::Randomized);
    assert_eq!(model.provenance().eig.solver, EigSolver::Randomized);
}

#[test]
fn auto_solver_at_small_l_is_byte_identical_to_dense() {
    // auto only switches to the sketch when m + oversample < l/4; at
    // l = 128, m = 32 it must resolve dense and reproduce the dense run
    // bit-for-bit (the rng never sees a Gaussian-panel draw)
    let ds = registry::generate("moons", 700, 6);
    let mut dense_cfg = cfg(Method::Nystrom);
    dense_cfg.m = 32;
    dense_cfg.eig_solver = EigSolver::Dense;
    let mut auto_cfg = dense_cfg.clone();
    auto_cfg.eig_solver = EigSolver::Auto;
    let a = Pipeline::with_compute(dense_cfg, Compute::reference()).run(&ds).unwrap();
    let b = Pipeline::with_compute(auto_cfg.clone(), Compute::reference()).run(&ds).unwrap();
    assert_eq!(a.labels, b.labels, "auto->dense must not perturb a single label");
    assert_eq!(a.obj_curve.len(), b.obj_curve.len());
    for (x, y) in a.obj_curve.iter().zip(&b.obj_curve) {
        assert_eq!(x.to_bits(), y.to_bits(), "objective curves must be byte-equal");
    }
    let (_, report) = Pipeline::with_compute(auto_cfg, Compute::reference()).fit(&ds).unwrap();
    assert_eq!(report.eig.solver, EigSolver::Dense, "auto must have resolved dense here");
}

#[test]
fn e2e_quality_ordering_holds_at_small_scale() {
    // the paper's qualitative claim, tested end-to-end: APNC beats the
    // 2-Stages sanity baseline on a hard mirrored dataset
    let Some(pjrt) = pjrt_or_skip() else { return };
    let ds = registry::generate("covtype", 4000, 13);
    let spec = registry::spec("covtype").unwrap();
    let mut rng = apnc::rng::Pcg::seeded(13);
    let kernel = spec.kernel.build(&ds.x, ds.d, &mut rng);

    let apnc_out = {
        let mut c = cfg(Method::Nystrom);
        c.l = 256;
        c.m = 256;
        c.kernel = Some(kernel);
        Pipeline::with_compute(c, pjrt).run(&ds).unwrap()
    };
    let two_stage = apnc::baselines::two_stage::cluster(
        &ds.x,
        ds.n,
        ds.d,
        kernel,
        &apnc::baselines::two_stage::TwoStageConfig {
            k: ds.k,
            l: 256,
            max_iters: 15,
            seed: 13,
            restarts: 2,
        },
    );
    let ts_nmi = apnc::metrics::nmi(&two_stage.labels, &ds.labels);
    assert!(
        apnc_out.nmi > ts_nmi - 0.02,
        "APNC ({}) should not lose to 2-Stages ({ts_nmi})",
        apnc_out.nmi
    );
}
