//! Correctness harness for the randomized truncated eigensolver
//! (`linalg::eigh_rand`, PR 7) — the three contracts the coefficient
//! reducer leans on:
//!
//! (1) **Spectral accuracy.** On matrices with a decaying spectrum (the
//! shape Nyström Gram matrices have) the top-m Ritz values must match
//! the dense `eigh` within rtol 1e-4 and the Ritz subspace must align
//! with the dense top-m invariant subspace — compared via orthogonal
//! projectors, so per-vector sign flips and within-eigenspace rotations
//! don't count as error.
//!
//! (2) **Thread parity.** Bit-identical output across 1/2/7/8 threads at
//! a fixed seed: the Gaussian panel is drawn sequentially and every GEMM
//! merges in fixed chunk order, so the thread count must not leak into a
//! single bit.
//!
//! (3) **Replay.** Same seed + same config twice → byte-equal output.
//!
//! `parallel::set_threads` is process-wide, so the test that flips it
//! serializes on `THREADS_LOCK` (same pattern as `eigh_parity.rs`).

use std::sync::Mutex;

use apnc::linalg::{eigh, eigh_rand, Eigh, Matrix};
use apnc::parallel;
use apnc::rng::Pcg;

static THREADS_LOCK: Mutex<()> = Mutex::new(());

/// Symmetric n×n matrix with the prescribed spectrum: an orthonormal
/// basis V from the dense eigh of a random SPD matrix, reassembled as
/// `V diag(spec) Vᵀ`. `spec[i]` is the i-th **largest** eigenvalue.
fn matrix_with_spectrum(n: usize, seed: u64, spec: &[f64]) -> Matrix {
    assert_eq!(spec.len(), n);
    let mut rng = Pcg::seeded(seed);
    let b = Matrix::from_fn(n, n, |_, _| rng.normal());
    let mut s = b.matmul_nt(&b);
    for i in 0..n {
        s[(i, i)] += 1.0;
    }
    let basis = eigh(&s).vectors; // orthonormal columns
    // column c of the basis carries spec[n - 1 - c] so that ascending
    // eigh order lines up with the descending `spec`
    let scaled = Matrix::from_fn(n, n, |r, c| basis[(r, c)] * spec[n - 1 - c]);
    scaled.matmul_nt(&basis)
}

/// Geometric decay 1, 1/2, 1/4, ... — every Gram-like test matrix here.
fn decaying_spec(n: usize) -> Vec<f64> {
    (0..n).map(|i| 0.5f64.powi(i as i32)).collect()
}

fn bits(e: &Eigh) -> (Vec<u64>, Vec<u64>) {
    (
        e.values.iter().map(|v| v.to_bits()).collect(),
        e.vectors.data().iter().map(|v| v.to_bits()).collect(),
    )
}

/// ‖V Vᵀ − W Wᵀ‖_max over n×n projector entries: rotation- and
/// sign-invariant distance between the two m-dimensional subspaces.
fn projector_gap(v: &Matrix, w: &Matrix) -> f64 {
    assert_eq!(v.rows(), w.rows());
    assert_eq!(v.cols(), w.cols());
    let pv = v.matmul_nt(v);
    let pw = w.matmul_nt(w);
    pv.data()
        .iter()
        .zip(pw.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max)
}

#[test]
fn top_m_eigenvalues_match_dense_within_rtol() {
    let (n, m) = (128usize, 10usize);
    let a = matrix_with_spectrum(n, 4101, &decaying_spec(n));
    let dense = eigh(&a);
    let mut rng = Pcg::seeded(4102);
    let rand = eigh_rand(&a, m, 8, 2, &mut rng);
    assert_eq!(rand.values.len(), m);
    // both ascending; dense's top-m live at the tail
    for i in 0..m {
        let want = dense.values[n - m + i];
        let got = rand.values[i];
        let rtol = (got - want).abs() / want.abs().max(1e-300);
        assert!(
            rtol < 1e-4,
            "Ritz value {i}: got {got:.12e}, dense says {want:.12e} (rtol {rtol:.2e})"
        );
    }
}

#[test]
fn ritz_subspace_aligns_with_dense_top_m() {
    let (n, m) = (96usize, 8usize);
    let a = matrix_with_spectrum(n, 4103, &decaying_spec(n));
    let dense = eigh(&a);
    // dense top-m eigenvectors, column order irrelevant to the projector
    let top = Matrix::from_fn(n, m, |r, c| dense.vectors[(r, n - m + c)]);
    let mut rng = Pcg::seeded(4104);
    let rand = eigh_rand(&a, m, 8, 2, &mut rng);
    let gap = projector_gap(&top, &rand.vectors);
    assert!(gap < 1e-4, "subspace projectors differ by {gap:.2e}");
    // and the Ritz vectors are orthonormal among themselves
    let g = rand.vectors.transpose().matmul(&rand.vectors);
    for r in 0..m {
        for c in 0..m {
            let want = if r == c { 1.0 } else { 0.0 };
            assert!((g[(r, c)] - want).abs() < 1e-10, "VᵀV[{r},{c}] = {}", g[(r, c)]);
        }
    }
}

#[test]
fn bit_identical_across_thread_counts() {
    let _g = THREADS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    // n large enough that the panel GEMMs span several parallel chunks
    let (n, m) = (384usize, 16usize);
    let a = matrix_with_spectrum(n, 4105, &decaying_spec(n));
    let run = |threads: usize| {
        parallel::set_threads(threads);
        let e = eigh_rand(&a, m, 8, 2, &mut Pcg::seeded(4106));
        parallel::set_threads(0);
        e
    };
    let base = bits(&run(1));
    for t in [2usize, 7, 8] {
        let got = bits(&run(t));
        assert_eq!(got.0, base.0, "Ritz values differ, threads={t}");
        assert_eq!(got.1, base.1, "Ritz vectors differ, threads={t}");
    }
}

#[test]
fn replay_with_same_seed_and_config_is_byte_equal() {
    let (n, m) = (160usize, 12usize);
    let a = matrix_with_spectrum(n, 4107, &decaying_spec(n));
    let once = bits(&eigh_rand(&a, m, 6, 1, &mut Pcg::new(4108, 0xD21E)));
    let twice = bits(&eigh_rand(&a, m, 6, 1, &mut Pcg::new(4108, 0xD21E)));
    assert_eq!(once, twice, "same seed + config must replay byte-equal");
    // and a different seed actually moves the bytes (the panel is live)
    let other = bits(&eigh_rand(&a, m, 6, 1, &mut Pcg::new(4109, 0xD21E)));
    assert_ne!(once.1, other.1, "different seed left the Ritz vectors untouched");
}
